#!/usr/bin/env python3
"""Validate a telemetry export against the ``cocco-telemetry`` schema.

Stdlib-only (runs in CI without the package on the path)::

    python scripts/check_telemetry_schema.py runs/telemetry.json

The file is the Chrome/Perfetto Trace Event Format's JSON object flavor
(``{"traceEvents": [...]}``) as written by ``python -m repro explore
--telemetry`` and ``python -m repro trace --perfetto`` — the same bytes
ui.perfetto.dev opens.  Checks the envelope (self-describing
``format``/``version`` keys, microsecond ``displayTimeUnit``), each
event's phase-specific contract ("X" complete events need non-negative
``ts``/``dur``, "C" counters need numeric args, "M" metadata must be a
process/thread name), and that the export is non-trivial (at least one
duration event).  Importable: ``validate_telemetry_dict(doc)`` returns a
list of error strings (empty == valid), which ``tests/test_obs.py``
reuses.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

TELEMETRY_FORMAT = "cocco-telemetry"
TELEMETRY_FORMAT_VERSIONS = (1,)

_PHASES = {"X", "C", "M"}
_META_NAMES = {"process_name", "thread_name"}


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _check_event(ev: Any, i: int, errs: List[str]) -> str:
    """Validate one trace event; returns its phase ('' when broken)."""
    where = f"traceEvents[{i}]"
    if not isinstance(ev, dict):
        errs.append(f"{where} must be an object")
        return ""
    ph = ev.get("ph")
    if ph not in _PHASES:
        errs.append(f"{where}.ph must be one of {sorted(_PHASES)}")
        return ""
    if not isinstance(ev.get("name"), str) or not ev["name"]:
        errs.append(f"{where}.name must be a non-empty string")
    if not isinstance(ev.get("pid"), int):
        errs.append(f"{where}.pid must be an int")
    args = ev.get("args")
    if ph == "M":
        if ev["name"] not in _META_NAMES:
            errs.append(f"{where}: metadata name must be one of "
                        f"{sorted(_META_NAMES)}")
        if not isinstance(args, dict) or \
                not isinstance(args.get("name"), str):
            errs.append(f"{where}.args.name must be a string label")
        return ph
    if not isinstance(ev.get("tid"), int):
        errs.append(f"{where}.tid must be an int")
    if not _num(ev.get("ts")) or ev.get("ts", -1) < 0:
        errs.append(f"{where}.ts must be a non-negative number (us)")
    if ph == "X":
        if not _num(ev.get("dur")) or ev.get("dur", -1) < 0:
            errs.append(f"{where}.dur must be a non-negative number (us)")
        if args is not None and not isinstance(args, dict):
            errs.append(f"{where}.args must be an object when present")
    else:  # "C"
        if not isinstance(args, dict) or not args:
            errs.append(f"{where}.args must be a non-empty object")
        elif not all(_num(v) for v in args.values()):
            errs.append(f"{where}.args values must all be numeric")
    return ph


def validate_telemetry_dict(doc: Any) -> List[str]:
    """Full-document validation; returns error strings (empty == valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["document must be a JSON object"]
    if doc.get("format") != TELEMETRY_FORMAT:
        errs.append(f"format must be {TELEMETRY_FORMAT!r}")
    if doc.get("version") not in TELEMETRY_FORMAT_VERSIONS:
        errs.append(f"version must be one of {TELEMETRY_FORMAT_VERSIONS}")
    if doc.get("displayTimeUnit") not in ("ms", "ns"):
        errs.append("displayTimeUnit must be 'ms' or 'ns'")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        errs.append("traceEvents must be a non-empty list")
        return errs
    counts: Dict[str, int] = {ph: 0 for ph in _PHASES}
    for i, ev in enumerate(events):
        ph = _check_event(ev, i, errs)
        if ph:
            counts[ph] += 1
        if len(errs) > 20:
            errs.append("... (further errors suppressed)")
            return errs
    if counts["X"] == 0:
        errs.append("export has no 'X' duration events — empty timeline")
    counters = doc.get("counters")
    if counters is not None:
        if not isinstance(counters, dict) or \
                not all(_num(v) for v in counters.values()):
            errs.append("counters must map names to numbers")
    meta = doc.get("meta")
    if meta is not None and not isinstance(meta, dict):
        errs.append("meta must be an object")
    return errs


def main(argv: List[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    path = argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as err:
        print(f"error: cannot read {path}: {err}", file=sys.stderr)
        return 1
    errs = validate_telemetry_dict(doc)
    if errs:
        for e in errs:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        print(f"{path}: INVALID ({len(errs)} errors)", file=sys.stderr)
        return 1
    events = doc["traceEvents"]
    n_x = sum(1 for ev in events if ev.get("ph") == "X")
    n_c = sum(1 for ev in events if ev.get("ph") == "C")
    kind = (doc.get("meta") or {}).get("kind", "unknown")
    print(f"{path}: valid {TELEMETRY_FORMAT} v{doc['version']} "
          f"({kind}) — {n_x} duration events, {n_c} counter samples")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
