#!/usr/bin/env python
"""CI smoke for `python -m repro serve-plans` (stdlib only).

Boots a real server subprocess on an OS-assigned port, then asserts the
acceptance behaviour of the plan service end to end over HTTP:

1. a cold request searches (``served_from == "search"``);
2. the identical request again replays from the store in bounded time
   (``served_from == "store"``, default bound 100 ms);
3. N concurrent *misses* of one new spec perform exactly one search —
   ``/stats`` reports ``dedup_joins == N-1`` and ``searches`` grew by 1;
4. every response carries identical result bytes for identical specs, and
   ``/stats`` matches the request history (requests/hits/misses add up);
5. ``GET /metrics`` serves parseable Prometheus text whose per-tier
   request histograms agree with the /stats ledger, and whose counters
   are monotone across scrapes.

Exit 0 on success; nonzero with a diagnostic on any violation.  Usage::

    python scripts/smoke_serve_plans.py [--hit-budget-ms 100]
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"
sys.path.insert(0, str(SRC))

from repro.api import ExploreSpec  # noqa: E402
from repro.core import HWSpace, Objective  # noqa: E402
from repro.serve.plans import (  # noqa: E402
    fetch_metrics,
    fetch_stats,
    request_plan,
)


def parse_metrics(text: str) -> dict:
    """Parse Prometheus text exposition into {name{labels}: value}."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        try:
            key, raw = line.rsplit(" ", 1)
            out[key] = float(raw)
        except ValueError:
            fail(f"unparseable /metrics line: {line!r}")
    return out


def spec_for(seed: int) -> ExploreSpec:
    return ExploreSpec(workload="synthetic:layered:10?seed=4",
                       strategy="greedy",
                       objective=Objective(metric="ema", alpha=None),
                       hw=HWSpace(mode="fixed"),
                       sample_budget=200, seed=seed)


def fail(msg: str) -> None:
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def wait_for_url(port_file: Path, proc: subprocess.Popen,
                 timeout: float = 30.0) -> str:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            fail(f"server exited early with rc={proc.returncode}")
        if port_file.exists():
            url = port_file.read_text().strip()
            if url:
                return url
        time.sleep(0.05)
    fail("server did not write --port-file in time")
    raise AssertionError  # unreachable


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hit-budget-ms", type=float, default=100.0,
                    help="max server-side latency for a store hit")
    ap.add_argument("--dedup-fanout", type=int, default=6)
    args = ap.parse_args()

    tmp = Path(tempfile.mkdtemp(prefix="serve-plans-smoke-"))
    port_file = tmp / "url"
    env = dict(os.environ, PYTHONPATH=str(SRC))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "serve-plans",
         "--store-dir", str(tmp / "store"), "--port", "0",
         "--port-file", str(port_file), "--workers", "2"],
        env=env, cwd=REPO,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        url = wait_for_url(port_file, proc)
        print(f"server up at {url}")

        # 1+2: cold search, then a store hit answered within budget
        cold = request_plan(url, spec_for(seed=0))
        if cold["served_from"] != "search":
            fail(f"cold request served from {cold['served_from']!r}")
        warm = request_plan(url, spec_for(seed=0))
        if warm["served_from"] != "store":
            fail(f"repeat request served from {warm['served_from']!r}")
        if warm["result"] != cold["result"]:
            fail("store replay is not bitwise-identical to the search")
        if warm["latency_ms"] > args.hit_budget_ms:
            fail(f"store hit took {warm['latency_ms']:.1f}ms "
                 f"(> {args.hit_budget_ms:.0f}ms budget)")
        print(f"store hit in {warm['latency_ms']:.1f}ms "
              f"(budget {args.hit_budget_ms:.0f}ms)")

        # 3: concurrent identical misses dedup to one search
        n = args.dedup_fanout
        fresh = spec_for(seed=1)
        with ThreadPoolExecutor(max_workers=n) as pool:
            docs = list(pool.map(lambda _: request_plan(url, fresh),
                                 range(n)))
        if len({json.dumps(d["result"], sort_keys=True)
                for d in docs}) != 1:
            fail("concurrent duplicates returned different results")
        deduped = sum(d["deduped"] for d in docs)
        stats = fetch_stats(url)["server"]
        if stats["searches"] != 2:
            fail(f"expected exactly 2 searches total (cold + fanout), "
                 f"/stats says {stats['searches']}")
        if stats["dedup_joins"] != n - 1 or deduped != n - 1:
            fail(f"expected {n - 1} dedup joins, /stats says "
                 f"{stats['dedup_joins']} (responses flagged {deduped})")
        print(f"dedup fanout: {n} concurrent requests -> 1 search, "
              f"{stats['dedup_joins']} joins")

        # 4: the ledger adds up
        if stats["requests"] != 2 + n:
            fail(f"/stats requests={stats['requests']}, expected {2 + n}")
        if stats["store_hits"] < 1 or stats["errors"] != 0:
            fail(f"unexpected /stats counters: {stats}")
        # 5: /metrics agrees with /stats and is monotone across scrapes
        m1 = parse_metrics(fetch_metrics(url))
        if m1["repro_plan_requests_total"] != stats["requests"]:
            fail(f"/metrics requests_total={m1['repro_plan_requests_total']}"
                 f" != /stats requests={stats['requests']}")
        # the search-tier histogram counts *responses* served by the
        # search path (dedup joiners included), not searches executed
        n_search_served = (stats["requests"] - stats["store_hits"]
                           - stats["zoo_hits"])
        for tier, want in (("store", stats["store_hits"]),
                           ("search", n_search_served)):
            got = m1[f'repro_plan_request_latency_seconds_count'
                     f'{{tier="{tier}"}}']
            if got != want:
                fail(f"/metrics latency histogram count for {tier!r} is "
                     f"{got}, /stats says {want}")
        extra = request_plan(url, spec_for(seed=0))
        if extra["served_from"] != "store":
            fail("warm re-request no longer hits the store")
        m2 = parse_metrics(fetch_metrics(url))
        regressed = [k for k, v in m1.items()
                     if "_total" in k or "_count" in k or "_bucket" in k
                     if m2.get(k, -1) < v]
        if regressed:
            fail(f"/metrics counters went backwards: {regressed}")
        if m2["repro_plan_requests_total"] != m1[
                "repro_plan_requests_total"] + 1:
            fail("requests_total did not advance across scrapes")
        print(f"metrics OK: {len(m1)} samples, counters monotone")

        print("smoke OK:", json.dumps({k: stats[k] for k in
              ("requests", "searches", "store_hits", "dedup_joins")}))
        return 0
    finally:
        proc.terminate()
        try:
            out = proc.communicate(timeout=10)[0]
        except subprocess.TimeoutExpired:
            proc.kill()
            out = proc.communicate()[0]
        if out:
            print("--- server log ---")
            print(out, end="")


if __name__ == "__main__":
    sys.exit(main())
